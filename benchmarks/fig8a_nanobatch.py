"""Fig. 8a — adaptive (AIMD) nano-batching vs fixed nano-batch sizes.

(a) Eq. 1 model: AIMD vs every fixed N under several compute/comm mixes.
(b) REAL wall-clock: grad-accumulated nano-batch scan on this host —
    fixed N sweep + the AIMD trajectory from train_loop.
"""
from __future__ import annotations

import numpy as np

from repro.configs import get_config
from repro.core.jobs import LoRAJobSpec
from repro.core.nanobatch import (AIMDController, optimal_nano,
                                  simulate_step_time)
from repro.core.ssm import valid_nano_counts
from repro.train.train_loop import train_group

from benchmarks.common import banner, save


def _aimd_final_time(rows, t_comp, t_comm, steps=40):
    ctl = AIMDController(rows=rows, max_n=rows)
    n = ctl.n
    for _ in range(steps):
        n = ctl.update(simulate_step_time(n, t_comp=t_comp, t_comm=t_comm))
    return simulate_step_time(ctl.n, t_comp=t_comp, t_comm=t_comm), ctl.n


def run(quick: bool = False) -> dict:
    banner("Fig 8a: AIMD nano-batching vs fixed")
    rows = 64
    regimes = [("comm-heavy", 0.010, 0.014),
               ("balanced", 0.010, 0.010),
               ("compute-heavy", 0.014, 0.004)]
    model_rows = []
    for name, tc, tm in regimes:
        fixed = {n: simulate_step_time(n, t_comp=tc, t_comm=tm)
                 for n in valid_nano_counts(rows)}
        t_aimd, n_aimd = _aimd_final_time(rows, tc, tm)
        best_n = min(fixed, key=fixed.get)
        worst = max(fixed.values())
        model_rows.append({
            "regime": name, "aimd_n": n_aimd,
            "aimd_ms": t_aimd * 1e3, "best_fixed_n": best_n,
            "best_fixed_ms": fixed[best_n] * 1e3,
            "worst_fixed_ms": worst * 1e3,
            "aimd_within_pct": 100 * (t_aimd / fixed[best_n] - 1)})
        print(f"  {name:14s}: AIMD N={n_aimd:3d} {t_aimd*1e3:6.2f}ms | "
              f"best fixed N={best_n:3d} {fixed[best_n]*1e3:6.2f}ms | "
              f"worst fixed {worst*1e3:6.2f}ms")

    # real wall-clock on host
    cfg = get_config("tinyllama-1.1b").reduced()
    jobs = [LoRAJobSpec(f"j{i}", rank=(4, 8)[i % 2], batch_size=4,
                        seq_len=32) for i in range(2)]
    real = {}
    for n in (1, 2, 4, 8):
        out = train_group(cfg, jobs, steps=4, impl="ref", block_t=8,
                          adaptive_nano=False, nano_batches=n, remat=False)
        real[n] = float(np.mean(out["report"].step_times[1:])) * 1e3
        print(f"  host fixed N={n}: {real[n]:.1f} ms/step")
    out_aimd = train_group(cfg, jobs, steps=8 if quick else 12, impl="ref",
                           block_t=8, adaptive_nano=True, remat=False)
    traj = out_aimd["report"].nano_history
    print(f"  host AIMD trajectory: {traj}")

    out = {"model": model_rows, "host_fixed_ms": real,
           "host_aimd_trajectory": traj}
    save("fig8a_nanobatch", out)
    return out


if __name__ == "__main__":
    run()
