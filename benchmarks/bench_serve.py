"""Serving benchmark — fused multi-adapter continuous batching vs
per-request solo decoding (DESIGN.md §13).

Publishes K mixed-rank adapters into an ``AdapterPool`` and drives the
``ServeEngine`` two ways over the same request set (seeded ragged
prompts, Poisson-ish arrivals):

  * SOLO: one request per batch, FCFS — the no-batching baseline every
    per-request-LoRA server pays;
  * FUSED: continuous-batching waves — each wave is every request that
    arrived while the previous wave was being served, decoded together
    through the ragged fused kernels with per-request adapters and
    per-row positions.

The PARITY GATE is the point: the fused waves must reproduce the solo
token ids EXACTLY (same argmax path — the per-row decode machinery
makes batch composition invisible to each request).  Throughput is
measured steady-state (shapes warmed, min over reps); the wave
simulator then replays the arrival schedule against real wall-clock
service times to get per-request latency percentiles.

Writes ``BENCH_serve.json`` at the repo root: fused/solo tokens/sec,
speedup, latency p50/p95, wave sizes, pool stats.  CI gates on
``parity_exact`` and archives the JSON in the perf trajectory.
"""
from __future__ import annotations

import dataclasses
import json
import pathlib
import time
from typing import List

import numpy as np
import jax

from repro.configs import get_config
from repro.core.jobs import LoRAJobSpec
from repro.core.ssm import SharedSuperModel
from repro.serve import AdapterPool, ServeEngine, ServeRequest

from benchmarks.common import banner

ROOT = pathlib.Path(__file__).resolve().parent.parent
OUT_PATH = ROOT / "BENCH_serve.json"


def _build(cfg, impl: str, block_t: int, ranks):
    specs = [LoRAJobSpec(f"adapter-{i}", rank=r, batch_size=1)
             for i, r in enumerate(ranks)]
    ssm = SharedSuperModel(cfg, specs, impl=impl, block_t=block_t)
    params, adapters = ssm.init(jax.random.PRNGKey(0))
    pool = AdapterPool(cfg, capacity=len(specs),
                       multiple=ssm.layout.multiple)
    pool.publish_group(specs, adapters, ssm.layout)
    engine = ServeEngine(cfg, params, pool, impl=impl, block_t=block_t)
    return specs, engine, pool


def _waves(arrivals: np.ndarray, base: float, inc: float) -> List[List[int]]:
    """Continuous-batching partition: wave = everything that arrived
    while the previous wave was (estimatedly) in service.  A size-B wave
    is modeled as ``base + inc * (B - 1)`` — one dispatch's fixed cost
    plus the amortized per-row marginal, which is what makes batching
    emerge: at loads past 1/base req/s the queue outruns solo service
    and waves grow until the marginal rate absorbs the arrivals.  The
    partition is fixed BEFORE timing so every wave shape can be warmed
    and the timed replay is deterministic."""
    N = len(arrivals)
    waves, i, clock = [], 0, float(arrivals[0])
    while i < N:
        j = i + 1
        while j < N and arrivals[j] <= clock:
            j += 1
        waves.append(list(range(i, j)))
        clock = max(clock, float(arrivals[i])) + base + inc * (j - i - 1)
        i = j
    return waves


def run(quick: bool = False) -> dict:
    banner("Serving: fused continuous batching vs per-request solo")
    cfg = dataclasses.replace(get_config("tinyllama-1.1b").reduced(),
                              dtype="float32")
    impl, block_t = "xla", 8
    ranks = (16, 8, 4) if quick else (16, 8, 8, 4)
    N = 8 if quick else 24
    T = 4 if quick else 8
    reps = 2 if quick else 3

    specs, engine, pool = _build(cfg, impl, block_t, ranks)
    rng = np.random.default_rng(0)
    reqs = [ServeRequest(
        prompt=rng.integers(1, cfg.vocab_size,
                            size=int(rng.integers(4, 14)), dtype=np.int32),
        adapter=specs[i % len(specs)].job_id, max_new_tokens=T)
        for i in range(N)]

    # ---- parity gate (also warms the solo + full-batch shapes)
    solo = [engine.serve([r])[0] for r in reqs]
    fused_all = engine.serve(reqs)
    parity = all(np.array_equal(a.tokens, b.tokens)
                 for a, b in zip(fused_all, solo))
    print(f"  fused-vs-solo exact token parity: {parity}  "
          f"(N={N}, K={len(ranks)}, ranks={ranks})")
    assert parity, "fused batch diverged from solo decode"

    # ---- steady-state throughput (shapes warm, min over reps)
    t_f = t_s = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        engine.serve(reqs)
        t_f = min(t_f, time.perf_counter() - t0)
        t0 = time.perf_counter()
        for r in reqs:
            engine.serve([r])
        t_s = min(t_s, time.perf_counter() - t0)
    tokens = N * T
    fused_tps, solo_tps = tokens / t_f, tokens / t_s
    speedup = fused_tps / solo_tps
    print(f"  solo  {solo_tps:8.1f} tok/s   ({t_s*1e3:7.1f} ms for "
          f"{N} requests, one at a time)")
    print(f"  fused {fused_tps:8.1f} tok/s   ({t_f*1e3:7.1f} ms, one "
          f"batch)  x{speedup:.2f} vs solo")

    # ---- continuous-batching replay: real wall times, fixed partition.
    # Arrivals scale to the measured service rates: the mean
    # inter-arrival sits between the fused amortized per-request time
    # (t_f/N) and the solo per-request time (t_s/N), so the offered
    # load is the same fraction of capacity on any machine — beyond
    # what one-at-a-time serving sustains (solo queue grows without
    # bound) yet within fused capacity once waves grow enough to
    # amortize the dispatch.
    arrivals = np.cumsum(rng.exponential(2.0 * t_f / N, size=N))
    arrivals -= arrivals[0]                     # first request at t=0
    waves = _waves(arrivals, base=t_s / N, inc=t_f / N)
    for w in waves:                              # warm every wave shape
        engine.serve([reqs[k] for k in w])
    lat_f = np.zeros(N)
    clock = 0.0
    for w in waves:
        batch = [reqs[k] for k in w]
        start = max(clock, float(arrivals[w[-1]]))
        t0 = time.perf_counter()
        engine.serve(batch)
        done = start + (time.perf_counter() - t0)
        for k in w:
            lat_f[k] = done - arrivals[k]
        clock = done
    lat_s = np.zeros(N)
    clock = 0.0
    for i, r in enumerate(reqs):
        start = max(clock, float(arrivals[i]))
        t0 = time.perf_counter()
        engine.serve([r])
        done = start + (time.perf_counter() - t0)
        lat_s[i] = done - arrivals[i]
        clock = done
    p = lambda a, q: float(np.percentile(a, q) * 1e3)
    print(f"  latency p50/p95  fused {p(lat_f,50):7.1f}/{p(lat_f,95):7.1f}"
          f" ms   solo {p(lat_s,50):7.1f}/{p(lat_s,95):7.1f} ms   "
          f"({len(waves)} waves, sizes {[len(w) for w in waves]})")

    out = {
        "config": {"model": cfg.name, "reduced": True, "impl": impl,
                   "block_t": block_t, "ranks": list(ranks),
                   "requests": N, "max_new_tokens": T, "reps": reps,
                   "quick": quick},
        "parity_exact": parity,
        "fused_tokens_per_s": fused_tps,
        "solo_tokens_per_s": solo_tps,
        "fused_vs_solo_x": speedup,
        "latency_ms": {"fused_p50": p(lat_f, 50), "fused_p95": p(lat_f, 95),
                       "solo_p50": p(lat_s, 50), "solo_p95": p(lat_s, 95)},
        "waves": [len(w) for w in waves],
        "pool_stats": dict(pool.stats),
    }
    OUT_PATH.write_text(json.dumps(out, indent=2) + "\n")
    print(f"  wrote {OUT_PATH}")
    return out


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    run(quick=ap.parse_args().quick)
