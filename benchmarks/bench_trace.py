"""Trace-driven survival benchmark — the live controller under a real
arrival process, with and without injected faults (DESIGN.md §12).

Replays a synthetic ACMETrace-style trace (cluster/trace.generate,
rescaled to bench wall clock) against the EXECUTING ClusterController
via cluster/harness.TraceRunner, and writes ``BENCH_trace.json`` with
MEASURED distributions:

  * per-job JCT (avg/p50/p95), cluster throughput, utilization — the
    paper's §4.1 metrics, measured on real training steps rather than
    the analytic simulator;
  * the same run with a deterministic ``FaultPlan`` (worker death
    mid-chunk, submesh loss; plus a stuck worker and a corrupted
    checkpoint file in full mode): per-fault detection latency, restore
    time, and steps lost, plus the survival gates — zero lost jobs,
    every fault recovered, steps lost bounded by the checkpoint period.

Run as a script to force a virtual device count:
``python -m benchmarks.bench_trace --quick --inject-faults --devices 8``.
"""
from __future__ import annotations

import os
import sys


def _peek_devices_arg(argv):
    for i, a in enumerate(argv):
        if a == "--devices" and i + 1 < len(argv):
            return argv[i + 1]
        if a.startswith("--devices="):
            return a.split("=", 1)[1]
    return None


if __name__ == "__main__":
    _spec = _peek_devices_arg(sys.argv)
    if _spec:
        try:
            _need = int(_spec)
        except ValueError:
            _need = 0
        _flags = os.environ.get("XLA_FLAGS", "")
        if _need > 1 and \
                "xla_force_host_platform_device_count" not in _flags:
            os.environ["XLA_FLAGS"] = (
                f"{_flags} --xla_force_host_platform_device_count={_need}"
            ).strip()

import dataclasses
import json
import pathlib
import tempfile

import jax

from repro.configs import get_config
from repro.cluster.controller import ClusterController
from repro.cluster.faults import FaultPlan, FaultSpec
from repro.core.scheduler import SchedulerConfig
from repro.cluster.harness import TraceRunner
from repro.cluster.trace import TraceConfig, generate, validate_trace

from benchmarks.common import banner

ROOT = pathlib.Path(__file__).resolve().parent.parent
OUT_PATH = ROOT / "BENCH_trace.json"
MODEL = "tinyllama-1.1b"
CHUNK = 2
CKPT_EVERY = 1           # every collected chunk -> period = CHUNK steps


def _trace(n_jobs: int, quick: bool, pool: int):
    """A bench-sized slice of the synthetic trace: the generator's
    burst/arrival structure and rank/batch skew survive; budgets and
    sequence lengths shrink to what a CI leg can train for real."""
    raw = generate(TraceConfig(months=1, jobs_per_month=4 * n_jobs,
                               base_models=(MODEL,), seed=7))[:n_jobs]
    lo, hi = (6, 18) if quick else (12, 40)
    jobs = [dataclasses.replace(
        j, seq_len=32, batch_size=min(j.batch_size, 4),
        gpus=min(j.gpus, max(1, pool // 4)),
        steps_budget=lo + (j.steps_budget % (hi - lo)))
        for j in raw]
    # satellite: infeasible jobs fail here, not deep inside partitioning
    return validate_trace(jobs, pool_chips=pool, models=(MODEL,))


def _fault_plan(jobs, quick: bool) -> FaultPlan:
    """Deterministic victims: the longest-budget jobs are guaranteed to
    still be running when their trigger step arrives."""
    by_budget = sorted(jobs, key=lambda j: -j.steps_budget)
    specs = [
        FaultSpec("worker_death", job_id=by_budget[0].job_id,
                  at_step=2, phase="inflight"),
        FaultSpec("submesh_loss", job_id=by_budget[1].job_id,
                  at_step=3, phase="boundary"),
    ]
    if not quick:
        specs.append(FaultSpec("corrupt_checkpoint",
                               job_id=by_budget[2].job_id, at_step=4,
                               phase="boundary"))
        specs.append(FaultSpec("stuck_worker",
                               job_id=by_budget[3].job_id, at_step=2,
                               phase="boundary", stuck_s=300.0))
    return FaultPlan(specs, seed=7)


def _controller(plan, quick: bool, sched=None, concurrency=None):
    cfg = get_config(MODEL).reduced()
    ckpt = tempfile.mkdtemp(prefix="bench_trace_ckpt_")
    ctl = ClusterController(
        lambda m: cfg, impl="xla", block_t=8, lr=1e-2,
        sched=sched, concurrency=concurrency,
        chunk_size=CHUNK, seed=0, checkpoint_dir=ckpt,
        checkpoint_every=CKPT_EVERY, fault_plan=plan,
        max_restarts=3, backoff_base_s=0.2,
        # heartbeat detection: well past a healthy chunk (ms) but short
        # enough that a wedged pump is caught within the bench window;
        # a cold pump's compile is excused by the startup grace
        stuck_after=20.0 if quick else 45.0, startup_grace_s=300.0)
    ctl.register_cfg(MODEL, cfg)
    return ctl


def _run(jobs, plan, quick: bool, sched=None, concurrency=None) -> dict:
    ctl = _controller(plan, quick, sched=sched, concurrency=concurrency)
    runner = TraceRunner(ctl, jobs,
                         arrival_window_s=6.0 if quick else 20.0,
                         poll_s=0.05,
                         max_wall_s=900.0 if quick else 2400.0)
    res = runner.run()
    s = res.summary()
    s["jct_per_job_s"] = {j: l.jct_s for j, l in res.logs.items()}
    return s


def run(quick: bool = False, inject_faults: bool = True) -> dict:
    banner("Trace-driven cluster runtime: survival under fire")
    pool = len(jax.devices())
    n_jobs = 8 if quick else 24
    jobs = _trace(n_jobs, quick, pool)
    period = CKPT_EVERY * CHUNK
    out = {"config": {"devices": pool, "jobs": len(jobs),
                      "chunk_size": CHUNK,
                      "checkpoint_every": CKPT_EVERY,
                      "checkpoint_period_steps": period,
                      "model": f"{MODEL}-reduced", "quick": quick}}

    print(f"  pool {pool} devices, {len(jobs)} jobs, budgets "
          f"{min(j.steps_budget for j in jobs)}.."
          f"{max(j.steps_budget for j in jobs)} steps")
    base = _run(jobs, None, quick)
    print(f"  no faults : {base['completed']}/{base['jobs']} done in "
          f"{base['wall_s']:.1f}s  jct p50 {base['p50_jct_s']:.1f}s  "
          f"util {base['utilization']:.2f}")
    out["no_faults"] = base
    assert base["lost_jobs"] == 0 and not base["timed_out"], base

    # cross-system baselines: the SAME trace replayed with grouping
    # disabled.  "solo" is the mLoRA-style per-adapter regime —
    # singleton groups on their own concurrent submeshes; "sequential"
    # is the naive queue — singleton groups run one at a time.  Their
    # JCT/throughput distributions sit next to the fused run above so
    # the fused-vs-baseline comparison ships in one artifact.
    solo_sched = SchedulerConfig(max_group=1)
    out["baselines"] = {}
    for mode, conc in (("solo", None), ("sequential", "sequential")):
        b = _run(jobs, None, quick, sched=solo_sched, concurrency=conc)
        print(f"  {mode:>10s}: {b['completed']}/{b['jobs']} done in "
              f"{b['wall_s']:.1f}s  jct p50 {b['p50_jct_s']:.1f}s  "
              f"util {b['utilization']:.2f}")
        assert b["lost_jobs"] == 0 and not b["timed_out"], (mode, b)
        out["baselines"][mode] = b

    if inject_faults:
        plan = _fault_plan(jobs, quick)
        faulted = _run(jobs, plan, quick)
        rec = faulted["recovery"]
        print(f"  faulted   : {faulted['completed']}/{faulted['jobs']} "
              f"done in {faulted['wall_s']:.1f}s  "
              f"faults {rec['faults']} recovered {rec['recovered']}  "
              f"max steps lost {rec['max_steps_lost']}")
        for f in faulted["failures"]:
            print(f"    {f['kind']:>18s} {'+'.join(f['gkey'])[:28]:28s} "
                  f"detect {f['detect_latency_s']*1e3:7.1f}ms  "
                  f"restore {f['restore_s']:6.2f}s  "
                  f"lost {max(list(f['steps_lost'].values()) or [0])}")
        out["faults"] = faulted
        out["faults_injected"] = len(plan.faults)
        out["faults_fired"] = len(plan.fired)
        # the survival contract IS the acceptance criterion — fail the
        # bench, not just the CI gate, when it breaks
        assert faulted["lost_jobs"] == 0, faulted
        assert rec["recovered"] == rec["faults"] == len(plan.fired), \
            faulted
        for f in faulted["failures"]:
            if f["kind"] in ("worker_death", "submesh_loss"):
                worst = max(list(f["steps_lost"].values()) or [0])
                assert worst <= period, (f, period)

    OUT_PATH.write_text(json.dumps(out, indent=2) + "\n")
    print(f"  wrote {OUT_PATH}")
    return out


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--inject-faults", action="store_true")
    ap.add_argument("--devices", type=int, default=None,
                    help="force a virtual host device count (script "
                         "mode only; e.g. 8 for the CI leg)")
    a = ap.parse_args()
    run(quick=a.quick, inject_faults=a.inject_faults)
