"""Fig. 2 — naive batching can help OR hurt aggregate throughput.

Reprices the paper's motivating experiment with the calibrated cost
model: complementary jobs (small + small / small + large on shared
weights) gain; compute-saturated pairs and cross-node groupings regress.
"""
from __future__ import annotations

from repro.configs import get_config
from repro.core.jobs import LoRAJobSpec
from repro.core import throughput as tp

from benchmarks.common import banner, save


def _per_chip(cfg, jobs, chips, spans=False, fused=True):
    """samples/sec/chip — the cluster-level currency a shared pool cares
    about (freed chips serve queued jobs)."""
    t = tp.group_step_cost(cfg, jobs, chips, spans_nodes=spans,
                           kernel_fused=fused).total
    return sum(j.batch_size for j in jobs) / t / chips


def run(quick: bool = False) -> dict:
    banner("Fig 2: naive batching helps or hurts (per-chip throughput)")
    cfg = get_config("recurrentgemma-9b")
    mk = lambda jid, r, b, g, s=512: LoRAJobSpec(jid, rank=r, batch_size=b,
                                                 seq_len=s, gpus=g)
    j1 = mk("job1-small", 4, 1, 2)
    j2 = mk("job2-saturated", 16, 8, 16, s=2048)
    j3 = mk("job3-small", 8, 2, 2)
    j2b = mk("job2b-saturated", 16, 8, 16, s=2048)

    rows = []
    cases = [
        # (name, jobs, grouped chips, spans, fused)
        ("1+3 naive pooled union", [j1, j3], 4, False, False),
        ("1+3 tLoRA fused", [j1, j3], 4, False, True),
        ("1+3 tLoRA fused+elastic (2 chips)", [j1, j3], 2, False, True),
        ("1+2 naive small+saturated", [j1, j2], 18, False, False),
        ("2+2' naive two saturated", [j2, j2b], 32, False, False),
        ("2+2' naive CROSS-NODE", [j2, j2b], 32, True, False),
    ]
    for name, jobs, chips, spans, fused in cases:
        solo = sum(j.batch_size / tp.group_step_cost(cfg, [j], j.gpus).total
                   for j in jobs) / sum(j.gpus for j in jobs)
        grouped = _per_chip(cfg, jobs, chips, spans, fused)
        deltas = tp.slowdowns(cfg, jobs, chips, spans_nodes=spans,
                              kernel_fused=fused)
        rows.append({"case": name,
                     "isolated_per_chip": round(solo, 3),
                     "batched_per_chip": round(grouped, 3),
                     "gain_x": round(grouped / solo, 3),
                     "max_slowdown": round(max(deltas.values()), 2)})
        print(f"  {name:34s} per-chip {solo:6.3f} -> {grouped:6.3f} "
              f"(x{grouped/solo:.2f})  worst slowdown "
              f"{max(deltas.values()):.2f}")

    gains = [r["gain_x"] for r in rows]
    verdict = {
        "some_groupings_help": max(gains) > 1.10,
        "some_groupings_hurt": min(gains) < 1.00,
    }
    print(f"  => groupings help (max x{max(gains):.2f}) AND hurt "
          f"(min x{min(gains):.2f}) — Fig. 2 reproduced: "
          f"{all(verdict.values())}")
    out = {"rows": rows, "verdict": verdict}
    save("fig2_naive_batching", out)
    return out


if __name__ == "__main__":
    run()
