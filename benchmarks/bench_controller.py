"""Controller benchmark — concurrent multi-group execution + online
oracle calibration (DESIGN.md §9).

Two headline numbers, written to ``BENCH_controller.json``:

  * ``concurrent_x``: wall-clock of 2 fused groups training on disjoint
    per-group submeshes CONCURRENTLY (threaded chunk loops) vs the same
    partition executed sequentially — the win the ClusterController
    exists for.  The scheduler assigns each group 1 chip, so the
    allocator carves two 1-device submeshes out of the pool (extra
    devices stay free for arrivals); concurrency then overlaps the
    groups' host-serial fractions, which dominate small-model steps.
  * ``calibration_x``: mean relative step-time error of the throughput
    oracle before vs after online calibration, measured on the same
    execution-backed simulator run (StepRecord.predicted vs
    .predicted_cal) — closing the §4.1 loop must make the oracle
    STRICTLY better on the machine it observes.
  * ``regroup_stall_x``: per-transition stall (seconds the affected
    groups are NOT training) for the same live merge executed
    stop-the-world (fence first, then rebuild + compile inside the
    pause window) vs overlapped (destination assembled and
    warm-compiled ahead of the fence; only the state handoff is paid) —
    the §11 zero-stall control plane headline.

Run as a script to force a virtual device count (like bench_step_loop's
``--mesh``): ``python -m benchmarks.bench_controller --devices 8``.
"""
from __future__ import annotations

import os
import sys


def _peek_devices_arg(argv):
    for i, a in enumerate(argv):
        if a == "--devices" and i + 1 < len(argv):
            return argv[i + 1]
        if a.startswith("--devices="):
            return a.split("=", 1)[1]
    return None


if __name__ == "__main__":
    _spec = _peek_devices_arg(sys.argv)
    if _spec:
        try:
            _need = int(_spec)
        except ValueError:
            _need = 0
        _flags = os.environ.get("XLA_FLAGS", "")
        if _need > 1 and \
                "xla_force_host_platform_device_count" not in _flags:
            os.environ["XLA_FLAGS"] = (
                f"{_flags} --xla_force_host_platform_device_count={_need}"
            ).strip()

import json
import pathlib
import time

import jax

from repro.configs import get_config
from repro.core.jobs import LoRAJobSpec
from repro.cluster.controller import ClusterController
from repro.cluster.execution import ExecutionBackend
from repro.cluster.simulator import ClusterConfig, ClusterSimulator, \
    tlora_policy

from benchmarks.common import banner

ROOT = pathlib.Path(__file__).resolve().parent.parent
OUT_PATH = ROOT / "BENCH_controller.json"
CHUNK = 4


def _build_controller(concurrency: str, seed: int = 0):
    cfg = get_config("tinyllama-1.1b").reduced()
    ctl = ClusterController(lambda m: cfg, impl="xla", block_t=8,
                            lr=1e-3, remat=False, chunk_size=CHUNK,
                            concurrency=concurrency, seed=seed)
    gkeys = []
    for g in range(2):
        for i in range(2):
            ctl.submit(LoRAJobSpec(f"g{g}j{i}", rank=(8, 16)[i],
                                   batch_size=2, seq_len=64,
                                   base_model=cfg.name, gpus=1))
        gkeys.append((f"g{g}j0", f"g{g}j1"))
    # scheduler assignment: 1 chip per group -> two 1-device submeshes
    ctl.apply_grouping(gkeys, chips=[1, 1])
    ctl.run(CHUNK)                           # compile the chunked steps
    return ctl


def _bench_concurrency(steps: int, reps: int) -> dict:
    """2 groups, disjoint submeshes: threaded vs sequential wall-clock.
    Interleaved reps so host load drift hits both modes equally."""
    ctl_seq = _build_controller("sequential")
    ctl_conc = _build_controller("threads")
    devs = ctl_conc.group_devices()
    t_seq = t_conc = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        ctl_seq.run(steps)
        t_seq = min(t_seq, time.perf_counter() - t0)
        t0 = time.perf_counter()
        ctl_conc.run(steps)
        t_conc = min(t_conc, time.perf_counter() - t0)
    x = t_seq / t_conc
    print(f"  sequential {t_seq:7.3f}s   concurrent {t_conc:7.3f}s   "
          f"x{x:.2f}  (2 groups, submeshes "
          f"{[list(d) for d in devs.values()]})")
    return {"sequential_s": t_seq, "concurrent_s": t_conc,
            "concurrent_x": x, "groups": 2,
            "group_devices": {"-".join(k): list(v)
                              for k, v in devs.items()},
            "partitioned": ctl_conc.partition}


def _wait(pred, timeout: float = 600.0) -> None:
    t0 = time.perf_counter()
    while not pred():
        if time.perf_counter() - t0 > timeout:
            raise TimeoutError("bench wait timed out")
        time.sleep(0.01)


def _bench_regroup(reps: int) -> dict:
    """Per-transition regroup stall under load (DESIGN.md §11).

    Both modes perform the SAME live merge — two 2-job groups fused
    into one — while the source chunk pumps keep stepping.
    Stop-the-world fences first and pays dissolve + rebuild + compile
    inside the pause window; overlapped assembles the destination from
    stale snapshots and warm-compiles it BEFORE the fence, so the
    window only contains the replay-exact state handoff."""
    evs = {"stop_the_world": [], "overlapped": []}
    for rep in range(reps):
        for mode, overlap in (("stop_the_world", False),
                              ("overlapped", True)):
            ctl = _build_controller("threads", seed=rep)
            g0, g1 = list(ctl.group_devices())
            merged = g0 + g1
            ctl.begin(10_000)                 # pump far past bench end
            _wait(lambda: min(ctl.steps_done(j) for j in merged)
                  >= 2 * CHUNK)               # warm steady-state
            if overlap:
                ctl.prewarm_async([merged], chips=[2])
            ctl.apply_grouping([merged], chips=[2], overlap=overlap)
            ev = ctl.regroup_log[-1]
            assert ev.mode == mode, (ev.mode, mode)
            fence = ev.fence_steps[merged[0]]
            # run past the handoff so resume cost is real, then stop
            _wait(lambda: ctl.steps_done(merged[0]) >= fence + CHUNK)
            ctl.drain()
            evs[mode].append(ev)

    def mean(mode, field):
        xs = [getattr(e, field) for e in evs[mode]]
        return sum(xs) / len(xs)

    fields = ("pause_s", "migrate_s", "compile_s", "resume_s",
              "assemble_s", "stall_s", "stall_group_s")
    breakdown = {}
    for m in evs:
        breakdown[m] = {f: mean(m, f) for f in fields}
        breakdown[m]["events"] = len(evs[m])
    stw = mean("stop_the_world", "stall_s")
    ov = mean("overlapped", "stall_s")
    x = stw / max(ov, 1e-9)
    print(f"  regroup stall: stop-the-world {stw:7.3f}s   "
          f"overlapped {ov:7.3f}s   x{x:.1f}  ({reps} rep(s), "
          f"compile inside window: "
          f"{breakdown['stop_the_world']['compile_s']:.3f}s vs "
          f"{breakdown['overlapped']['compile_s']:.3f}s)")
    return {"regroup_stall_stw_s": stw,
            "regroup_stall_overlap_s": ov,
            "regroup_stall_x": x,
            "regroup_breakdown": breakdown}


def _bench_calibration(quick: bool) -> dict:
    """Execution-backed simulator run: oracle error before vs after the
    online fit, on the SAME StepRecord stream."""
    def J(i, arr, budget, rank=4):
        return LoRAJobSpec(f"c{i}", rank=rank, batch_size=1, seq_len=32,
                           base_model="smollm-360m", steps_budget=budget,
                           arrival_time=arr, max_slowdown=2.0)

    trace = [J(0, 0.0, 20_000), J(1, 0.0, 20_000, rank=8),
             J(2, 40.0, 4_000, rank=2)]
    cc = ClusterConfig(total_chips=8, horizon=30.0, concurrency_cap=4,
                       reduced_models=True)
    backend = ExecutionBackend(steps_per_measure=2, block_t=8)
    sim = ClusterSimulator(cc, None, execution=backend)
    sim.policy = tlora_policy(sim._cfg_of,
                              calibrator=backend.calibrator)
    sim.run(trace, max_time=300.0 if quick else 700.0)

    recs = backend.records
    assert recs, "no execution observations recorded"
    err_uncal = sum(r.error for r in recs) / len(recs)
    err_cal = sum(r.error_cal for r in recs) / len(recs)
    print(f"  oracle mean rel error: uncalibrated {err_uncal:.3f}  "
          f"calibrated {err_cal:.3f}  "
          f"(x{err_uncal / max(err_cal, 1e-12):.1f} better, "
          f"{len(recs)} observations)")
    return {"oracle_err_uncal": err_uncal, "oracle_err_cal": err_cal,
            "calibration_x": err_uncal / max(err_cal, 1e-12),
            "observations": len(recs),
            "regroup_events": backend.regroup_events,
            "calibration": backend.calibrator.summary()}


def run(quick: bool = False) -> dict:
    banner("Controller: concurrent groups + online-calibrated oracle")
    n = len(jax.devices())
    steps = CHUNK * (3 if quick else 6)
    reps = 3 if quick else 5
    print(f"  device pool: {n}")
    out = {"config": {"devices": n, "chunk_size": CHUNK,
                      "steps_timed": steps, "reps": reps,
                      "model": "tinyllama-1.1b-reduced", "quick": quick}}
    out.update(_bench_concurrency(steps, reps))
    out.update(_bench_regroup(1 if quick else 2))
    out.update(_bench_calibration(quick))
    OUT_PATH.write_text(json.dumps(out, indent=2) + "\n")
    print(f"  wrote {OUT_PATH}")
    return out


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--devices", type=int, default=None,
                    help="force a virtual host device count (script "
                         "mode only; e.g. 8 for the CI leg)")
    a = ap.parse_args()
    run(quick=a.quick)
