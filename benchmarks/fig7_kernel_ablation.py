"""Fig. 7 — kernel fuser ablation.

Three levels:
  (a) REAL wall-clock on this host: one fused multi-LoRA train step vs
      the unfused per-adapter GEMM-pair baseline ("loop", K kernel
      launches) across group sizes K — the microbench analogue of the
      paper's PyTorch-native-kernel ablation.
  (b) fwd+bwd kernel ablation: value+grad of the fused LoRA op under the
      grouped backward (segment-dense custom VJP / grouped-wgrad pallas
      kernels) vs the legacy one-hot wgrad formulation vs the unfused
      per-adapter loop.
  (c) cluster-level: tLoRA vs tLoRA-w/o-Kernel-Fuser in the simulator.
"""
from __future__ import annotations

import dataclasses
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core.jobs import LoRAJobSpec
from repro.core.ssm import SharedSuperModel
from repro.data.pipeline import FusedBatcher
from repro.kernels import ops, ref
from repro.optim import adamw
from repro.optim.schedule import constant

from benchmarks.common import (banner, make_trace, run_systems, save,
                               summarize_systems)


def _time_step(cfg, jobs, impl, iters=5):
    ssm = SharedSuperModel(cfg, jobs, impl=impl, block_t=8)
    params, adapters = ssm.init(jax.random.PRNGKey(0))
    opt = adamw.init(adapters)
    fb = FusedBatcher(jobs, cfg.vocab_size, block_t=8)
    batch = {k: jnp.asarray(v) for k, v in fb.next_batch().items()}
    step = jax.jit(ssm.make_train_step(lr_fn=constant(1e-3), remat=False))
    adapters, opt, m = step(params, adapters, opt, batch)   # compile
    jax.block_until_ready(m["loss"])
    t0 = time.perf_counter()
    for _ in range(iters):
        adapters, opt, m = step(params, adapters, opt, batch)
        jax.block_until_ready(m["loss"])
    return (time.perf_counter() - t0) / iters


def _onehot_fused_lora(x, A, B, ids, ranks, scalings):
    """The legacy dense-over-K formulation whose AUTODIFF backward is the
    one-hot wgrad path this PR removed — kept here as the ablation
    baseline (einsum('tk,...') densifies every wgrad over all K)."""
    K, _, r_pad = A.shape
    lane = jnp.arange(r_pad)
    onehot = jax.nn.one_hot(ids, K, dtype=x.dtype)
    xa = jnp.einsum("td,kdr->tkr", x, A,
                    preferred_element_type=jnp.float32)
    xa = jnp.where(lane[None, None, :] < ranks[None, :, None],
                   xa, 0.0).astype(x.dtype)
    y = jnp.einsum("tkr,kro->tko", xa, B,
                   preferred_element_type=jnp.float32)
    y = y * scalings[None, :, None]
    return jnp.einsum("tko,tk->to", y,
                      onehot.astype(jnp.float32)).astype(x.dtype)


def _time_fwd_bwd(K: int, *, T=256, d=128, r_pad=16, block_t=32,
                  iters=5) -> dict:
    """Wall-clock one fwd+bwd of the fused LoRA op per backward impl.

    'grouped' is the compiled segment-dense custom VJP (the role the
    pallas grouped-wgrad kernels play on a real TPU — Mosaic cannot
    compile on CPU, and interpret-mode timings are not representative,
    so the pallas path is validated for *correctness* in
    tests/test_backward_kernels.py and priced here via its XLA twin)."""
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((T, d)).astype(np.float32))
    A = jnp.asarray((rng.standard_normal((K, d, r_pad)) * 0.3)
                    .astype(np.float32))
    B = jnp.asarray(((rng.standard_normal((K, r_pad, d)) * 0.3) + 0.1)
                    .astype(np.float32))
    ranks = jnp.asarray(rng.integers(1, r_pad + 1, size=K), jnp.int32)
    scal = jnp.asarray(16.0 / np.asarray(ranks), jnp.float32)
    ids = jnp.asarray(np.repeat(np.arange(K), T // K).astype(np.int32))

    def variant(fn):
        g = jax.jit(jax.value_and_grad(
            lambda x, A, B: (fn(x, A, B).astype(jnp.float32) ** 2).sum(),
            argnums=(0, 1, 2)))
        out = g(x, A, B)                                     # compile
        jax.block_until_ready(out[0])
        t0 = time.perf_counter()
        for _ in range(iters):
            out = g(x, A, B)
            jax.block_until_ready(out[0])
        return (time.perf_counter() - t0) / iters

    t = {
        "grouped": variant(lambda x, A, B: ops.fused_lora(
            x, A, B, ids, ranks, scal, impl="xla", equal_segments=True)),
        "onehot": variant(lambda x, A, B: _onehot_fused_lora(
            x, A, B, ids, ranks, scal)),
        "loop": variant(lambda x, A, B: ref.fused_lora_loop(
            x, A, B, ids, ranks, scal)),
    }
    return {"K": K,
            **{f"{k}_ms": v * 1e3 for k, v in t.items()},
            "grouped_vs_onehot_x": t["onehot"] / t["grouped"],
            "grouped_vs_loop_x": t["loop"] / t["grouped"]}


def run(quick: bool = False) -> dict:
    banner("Fig 7: kernel fuser ablation")
    cfg = get_config("tinyllama-1.1b").reduced()
    rows = []
    for K in (2, 4) if quick else (2, 4, 8):
        jobs = [LoRAJobSpec(f"j{i}", rank=(2, 4, 8, 16)[i % 4],
                            batch_size=1, seq_len=64)
                for i in range(K)]
        # fused = the grouped-GEMM formulation (one launch, all adapters);
        # unfused = one masked GEMM pair per adapter (K launches)
        t_fused = _time_step(cfg, jobs, "xla")
        t_loop = _time_step(cfg, jobs, "loop")
        rows.append({"K": K, "fused_ms": t_fused * 1e3,
                     "unfused_ms": t_loop * 1e3,
                     "speedup_x": t_loop / t_fused})
        print(f"  K={K}: fused {t_fused*1e3:7.1f}ms  "
              f"unfused {t_loop*1e3:7.1f}ms  "
              f"(fused x{t_loop/t_fused:.2f} faster)")

    bwd_rows = []
    for K in (2, 4) if quick else (2, 4, 8):
        r = _time_fwd_bwd(K, iters=3 if quick else 5)
        bwd_rows.append(r)
        print(f"  fwd+bwd K={K}: grouped {r['grouped_ms']:6.2f}ms  "
              f"one-hot {r['onehot_ms']:6.2f}ms  loop {r['loop_ms']:6.2f}ms"
              f"  (grouped x{r['grouped_vs_onehot_x']:.2f} vs one-hot, "
              f"x{r['grouped_vs_loop_x']:.2f} vs loop)")

    trace = make_trace(jobs=250 if quick else 600, seed=2)
    results = run_systems(trace, ("tlora", "tlora_no_kernel"))
    summ = summarize_systems(results)
    jct_gain = (summ["tlora_no_kernel"]["avg_jct_sec"]
                / summ["tlora"]["avg_jct_sec"])
    print(f"  cluster: disabling the kernel fuser inflates JCT x"
          f"{jct_gain:.2f} and drops util "
          f"{(summ['tlora']['utilization']-summ['tlora_no_kernel']['utilization'])*100:+.1f}pp")

    out = {"microbench": rows, "fwd_bwd_ablation": bwd_rows,
           "cluster": summ, "jct_inflation_without_fuser": jct_gain}
    save("fig7_kernel_ablation", out)
    return out


if __name__ == "__main__":
    run()
