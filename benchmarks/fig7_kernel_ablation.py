"""Fig. 7 — kernel fuser ablation.

Two levels:
  (a) REAL wall-clock on this host: one fused multi-LoRA train step vs
      the unfused per-adapter GEMM-pair baseline ("loop", K kernel
      launches) across group sizes K — the microbench analogue of the
      paper's PyTorch-native-kernel ablation.
  (b) cluster-level: tLoRA vs tLoRA-w/o-Kernel-Fuser in the simulator.
"""
from __future__ import annotations

import dataclasses
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core.jobs import LoRAJobSpec
from repro.core.ssm import SharedSuperModel
from repro.data.pipeline import FusedBatcher
from repro.optim import adamw
from repro.optim.schedule import constant

from benchmarks.common import (banner, make_trace, run_systems, save,
                               summarize_systems)


def _time_step(cfg, jobs, impl, iters=5):
    ssm = SharedSuperModel(cfg, jobs, impl=impl, block_t=8)
    params, adapters = ssm.init(jax.random.PRNGKey(0))
    opt = adamw.init(adapters)
    fb = FusedBatcher(jobs, cfg.vocab_size, block_t=8)
    batch = {k: jnp.asarray(v) for k, v in fb.next_batch().items()}
    step = jax.jit(ssm.make_train_step(lr_fn=constant(1e-3), remat=False))
    adapters, opt, m = step(params, adapters, opt, batch)   # compile
    jax.block_until_ready(m["loss"])
    t0 = time.perf_counter()
    for _ in range(iters):
        adapters, opt, m = step(params, adapters, opt, batch)
        jax.block_until_ready(m["loss"])
    return (time.perf_counter() - t0) / iters


def run(quick: bool = False) -> dict:
    banner("Fig 7: kernel fuser ablation")
    cfg = get_config("tinyllama-1.1b").reduced()
    rows = []
    for K in (2, 4) if quick else (2, 4, 8):
        jobs = [LoRAJobSpec(f"j{i}", rank=(2, 4, 8, 16)[i % 4],
                            batch_size=1, seq_len=64)
                for i in range(K)]
        # fused = the grouped-GEMM formulation (one launch, all adapters);
        # unfused = one masked GEMM pair per adapter (K launches)
        t_fused = _time_step(cfg, jobs, "xla")
        t_loop = _time_step(cfg, jobs, "loop")
        rows.append({"K": K, "fused_ms": t_fused * 1e3,
                     "unfused_ms": t_loop * 1e3,
                     "speedup_x": t_loop / t_fused})
        print(f"  K={K}: fused {t_fused*1e3:7.1f}ms  "
              f"unfused {t_loop*1e3:7.1f}ms  "
              f"(fused x{t_loop/t_fused:.2f} faster)")

    trace = make_trace(jobs=250 if quick else 600, seed=2)
    results = run_systems(trace, ("tlora", "tlora_no_kernel"))
    summ = summarize_systems(results)
    jct_gain = (summ["tlora_no_kernel"]["avg_jct_sec"]
                / summ["tlora"]["avg_jct_sec"])
    print(f"  cluster: disabling the kernel fuser inflates JCT x"
          f"{jct_gain:.2f} and drops util "
          f"{(summ['tlora']['utilization']-summ['tlora_no_kernel']['utilization'])*100:+.1f}pp")

    out = {"microbench": rows, "cluster": summ,
           "jct_inflation_without_fuser": jct_gain}
    save("fig7_kernel_ablation", out)
    return out


if __name__ == "__main__":
    run()
